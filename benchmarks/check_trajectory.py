"""Gate a fresh ``benchmarks/run.py --json`` output against the committed
perf trajectory (``BENCH_PR4.json`` at the repo root).

Checks, in order:

  1. the new run is ``ok`` (no benchmark module failed);
  2. **coverage** — every record name in the baseline appears in the new
     run (a refactor cannot silently drop a measured cell);
  3. **the serving claim** — every ``serve/.../paged_vs_fixed/...`` record
     in the new run shows the continuous-batching engine at or above
     ``--min-ratio`` × the fixed-slot engine's tokens/s (default 1.0:
     paged must not lose to fixed slots on the mixed-length workload);
  4. **the speculative claim** — every ``spec/spec_vs_plain/...`` record
     shows the speculative engine at or above ``--min-spec-ratio`` ×
     plain decode's tokens/s at its recorded acceptance rate (default
     1.0: an int4 draft must convert the paper's resolution saving into
     throughput, not lose it).  Presence is enforced by the coverage
     check against the committed baseline (``BENCH_PR5.json`` carries
     the speculative cells), so pre-PR-5 subset runs stay valid;
  5. **the sampling claim** — whenever speculative records exist, at
     least one ``spec/spec_sampling/...`` cell must exist and carry a
     numeric acceptance rate in ``[0, 1]``: the rejection-sampling
     acceptance path (PR 6) cannot silently fall out of the measured
     surface;
  6. **the observability claim** — every engine-throughput record
     (``serve/mesh*/fixed|paged/...``) must carry numeric ``occupancy``
     (> 0 rows) and ``ttft_ms`` (> 0) cells: PR 7 derives benchmark
     numbers from the serving metrics registry, and a refactor cannot
     silently drop the registry-backed cells from the measured surface;
  7. **the prefix-reuse claim** — every ``serve/prefix_reuse/
     warm_vs_cold`` record shows shared-prefix TTFT at or below cold-
     start TTFT (``ttft_ratio`` = cold/warm ≥ ``--min-prefix-ratio``,
     default 1.0) with non-zero prefix-hit and reused-token counters
     from the metrics registry (PR 8: the radix-index admission path
     cannot silently fall out of the measured surface).  Presence is
     enforced by coverage against ``BENCH_PR8.json``;
  8. **the fused-verify claim** — whenever speculative records exist, a
     ``spec/fused_verify/...`` cell must exist and show the fused
     layer-major verify window at or above ``--min-verify-ratio`` ×
     the scan oracle's speed (default 1.1: gathering each layer's pages
     once instead of W times must actually pay — PR 9).  Presence is
     enforced by coverage against ``BENCH_PR9.json``;
  9. **the overhead claim** — every ``serve/obs_overhead/...`` record
     shows the metrics-on engine at or above ``--min-obs-ratio`` × the
     recorder-less engine's tokens/s (default 0.95: a live metrics
     registry may cost at most 5 % — PR 10's sampled probes and
     profiler must keep the default-off path free).  Presence is
     enforced by coverage against ``BENCH_PR10.json``.

Absolute µs numbers are *not* compared — CI machines vary too much; the
trajectory tracks structure and engine-vs-engine ordering, which are
machine-independent.

Usage::

    python benchmarks/check_trajectory.py \
        --baseline BENCH_PR4.json --new /tmp/bench_new.json
"""
import argparse
import json
import sys
from pathlib import Path


def _parse_derived(derived: str) -> dict:
    out = {}
    for part in derived.split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


def check(baseline: dict, new: dict, min_ratio: float,
          min_spec_ratio: float = 1.0, min_prefix_ratio: float = 1.0,
          min_verify_ratio: float = 1.1, min_obs_ratio: float = 0.95) -> list:
    errors = []
    if not new.get("ok", False):
        errors.append(f"new run not ok: failed={new.get('failed')} "
                      f"errors={new.get('errors')}")
    base_names = {r["name"] for r in baseline.get("records", [])}
    new_names = {r["name"] for r in new.get("records", [])}
    missing = sorted(base_names - new_names)
    if missing:
        errors.append(f"records dropped vs baseline: {missing}")
    ratio_recs = [r for r in new.get("records", [])
                  if "/paged_vs_fixed/" in r["name"]]
    if not ratio_recs:
        errors.append("no paged_vs_fixed records in the new run")
    for rec in ratio_recs:
        ratio = _parse_derived(rec["derived"]).get("ratio")
        if ratio is None:
            errors.append(f"{rec['name']}: no ratio in derived")
        elif ratio < min_ratio:
            errors.append(
                f"{rec['name']}: continuous batching at {ratio:.2f}x fixed "
                f"slots (< required {min_ratio:.2f}x)")
    for rec in [r for r in new.get("records", [])
                if "/spec_vs_plain/" in r["name"]]:
        d = _parse_derived(rec["derived"])
        ratio = d.get("ratio")
        if ratio is None:
            errors.append(f"{rec['name']}: no ratio in derived")
        elif ratio < min_spec_ratio:
            errors.append(
                f"{rec['name']}: speculative decode at {ratio:.2f}x plain "
                f"(< required {min_spec_ratio:.2f}x) at acceptance "
                f"{d.get('acceptance')}")
    spec_plain = [r for r in new.get("records", [])
                  if "/spec_vs_plain/" in r["name"]]
    spec_sampling = [r for r in new.get("records", [])
                     if "/spec_sampling/" in r["name"]]
    if spec_plain and not spec_sampling:
        errors.append(
            "speculative records present but no spec_sampling cell — the "
            "rejection-sampling acceptance path is unmeasured")
    for rec in spec_sampling:
        acc = _parse_derived(rec["derived"]).get("acceptance")
        if not isinstance(acc, float) or not 0.0 <= acc <= 1.0:
            errors.append(
                f"{rec['name']}: acceptance {acc!r} is not a number in "
                f"[0, 1]")
    for rec in [r for r in new.get("records", [])
                if "/prefix_reuse/warm_vs_cold" in r["name"]]:
        d = _parse_derived(rec["derived"])
        ratio = d.get("ttft_ratio")
        if not isinstance(ratio, float):
            errors.append(f"{rec['name']}: no ttft_ratio in derived")
        elif ratio < min_prefix_ratio:
            errors.append(
                f"{rec['name']}: shared-prefix TTFT at {1 / ratio:.2f}x "
                f"cold start (cold/warm {ratio:.2f} < required "
                f"{min_prefix_ratio:.2f})")
        for key in ("hits", "reused_tokens"):
            v = d.get(key)
            if not isinstance(v, float) or v <= 0.0:
                errors.append(
                    f"{rec['name']}: {key} {v!r} is not positive — the "
                    f"prefix-reuse path went unmeasured")
    verify_recs = [r for r in new.get("records", [])
                   if "/fused_verify/" in r["name"]]
    if spec_plain and not verify_recs:
        errors.append(
            "speculative records present but no fused_verify cell — the "
            "fused verify-window kernel is unmeasured")
    for rec in verify_recs:
        ratio = _parse_derived(rec["derived"]).get("ratio")
        if ratio is None:
            errors.append(f"{rec['name']}: no ratio in derived")
        elif ratio < min_verify_ratio:
            errors.append(
                f"{rec['name']}: fused verify window at {ratio:.2f}x the "
                f"scan oracle (< required {min_verify_ratio:.2f}x)")
    for rec in [r for r in new.get("records", [])
                if "/obs_overhead/" in r["name"]]:
        ratio = _parse_derived(rec["derived"]).get("ratio")
        if not isinstance(ratio, float):
            errors.append(f"{rec['name']}: no ratio in derived")
        elif ratio < min_obs_ratio:
            errors.append(
                f"{rec['name']}: metrics-on engine at {ratio:.2f}x the "
                f"recorder-less engine (< required {min_obs_ratio:.2f}x — "
                f"observability overhead above budget)")
    engine_recs = [r for r in new.get("records", [])
                   if r["name"].startswith("serve/")
                   and ("/paged/" in r["name"] or "/fixed/" in r["name"])]
    for rec in engine_recs:
        d = _parse_derived(rec["derived"])
        for key in ("occupancy", "ttft_ms"):
            v = d.get(key)
            if not isinstance(v, float) or v <= 0.0:
                errors.append(
                    f"{rec['name']}: {key} {v!r} is not a positive number "
                    f"— registry-backed cells missing")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--new", required=True)
    ap.add_argument("--min-ratio", type=float, default=1.0,
                    help="required paged/fixed tokens-per-second ratio")
    ap.add_argument("--min-spec-ratio", type=float, default=1.0,
                    help="required speculative/plain tokens-per-second ratio")
    ap.add_argument("--min-prefix-ratio", type=float, default=1.0,
                    help="required cold/warm TTFT ratio for shared-prefix "
                         "admissions (prefix reuse must not slow TTFT)")
    ap.add_argument("--min-verify-ratio", type=float, default=1.1,
                    help="required fused/scan verify-window speed ratio "
                         "(the fused kernel must beat the per-token oracle)")
    ap.add_argument("--min-obs-ratio", type=float, default=0.95,
                    help="required metrics-on/recorder-less tokens-per-"
                         "second ratio (observability overhead budget)")
    args = ap.parse_args(argv)

    baseline = json.loads(Path(args.baseline).read_text())
    new = json.loads(Path(args.new).read_text())
    errors = check(baseline, new, args.min_ratio, args.min_spec_ratio,
                   args.min_prefix_ratio, args.min_verify_ratio,
                   args.min_obs_ratio)
    if errors:
        for e in errors:
            print(f"[trajectory] FAIL: {e}", file=sys.stderr)
        return 1
    n = len(new.get("records", []))
    print(f"[trajectory] OK: {n} records — coverage, paged>fixed and "
          "spec>plain hold")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
