"""Serving throughput: tokens/s vs slots x mesh shape.

Drives the continuous-batching ``ServeEngine`` on a tiny reduced config and
sweeps the decode-slot count against every mesh shape that fits the host
device count (fake devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to exercise the
sharded shapes — the CI ``bench-smoke`` job does).  Emitted per cell:
``us`` = µs per generated token, ``derived`` = tokens/s plus the request
mix, seeding the trajectory for the paper's "constrained resource growth
as problem size rises" serving claim.

Run:  PYTHONPATH=src python -m benchmarks.bench_serve_throughput
"""
import dataclasses
import time

import jax

from benchmarks.common import emit

SLOTS = (1, 2, 4)
MESH_SHAPES = ((1, 2), (2, 1), (2, 2), (2, 4))


def _tiny_cfg():
    from repro.configs import get_config

    cfg = get_config("qwen3-14b", reduced=True)
    return dataclasses.replace(
        cfg,
        num_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=128,
        num_heads=2,
        num_kv_heads=1,
        head_dim=32,
    )


def _drain(engine, prompts, max_new):
    for p in prompts:
        engine.submit(p, max_new_tokens=max_new)
    t0 = time.perf_counter()
    done = engine.run_until_drained()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.generated) for r in done)
    return n_tok, dt


def run(requests: int = 6, max_new: int = 8) -> None:
    from repro.models import model as MD
    from repro.serving import ServeEngine

    cfg = _tiny_cfg()
    params = MD.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [
        [(7 * i + j) % cfg.vocab_size for j in range(4)] for i in range(requests)
    ]

    n_dev = len(jax.devices())
    meshes = [None] + [
        jax.make_mesh((d, m), ("data", "model"))
        for d, m in MESH_SHAPES
        if d * m <= n_dev
    ]
    for mesh in meshes:
        tag = "1x1" if mesh is None else f"{mesh.shape['data']}x{mesh.shape['model']}"
        for slots in SLOTS:
            engine = ServeEngine(params, cfg, slots=slots, max_len=64, mesh=mesh)
            # first drain warms the jitted prefill/decode, second is timed
            _drain(engine, prompts[:1], 2)
            n_tok, dt = _drain(engine, prompts, max_new)
            tok_s = n_tok / max(dt, 1e-9)
            emit(
                f"serve/mesh{tag}/slots{slots}",
                dt / max(n_tok, 1) * 1e6,
                f"tok_s={tok_s:.1f};requests={requests};max_new={max_new}",
            )


if __name__ == "__main__":
    run()
