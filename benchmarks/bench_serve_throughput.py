"""Serving throughput: continuous batching (paged) vs fixed slots.

Drives both engines over a **mixed-length** request workload (the regime
continuous batching exists for) on a tiny reduced config, sweeping the
decode-batch size and every mesh shape that fits the host device count
(fake devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
to exercise the sharded cells — the CI jobs do).  Emitted per cell:
``us`` = µs per generated token, ``derived`` = tokens/s, mean decode-batch
occupancy and mean TTFT (ms) plus the request mix — all read from the
PR-7 metrics registry (each engine runs with a metrics-only
:class:`repro.serving.Recorder`, reset after the warm-up drain, so the
reported numbers and ``--metrics`` serving snapshots share one source of
truth); plus a ``paged_vs_fixed`` ratio record per batch size — the record
``benchmarks/check_trajectory.py`` gates on (paged must beat fixed slots,
and every engine cell must carry numeric ``occupancy``/``ttft_ms``).

The fixed-slot engine re-runs an eager whole-prompt prefill per admission
(every distinct prompt length is a fresh set of op shapes); the paged
engine prefils in fixed-width chunks through one compiled program and
interleaves them with decode — that is where the mixed-length win comes
from.

Run:  PYTHONPATH=src python -m benchmarks.run --only serve_throughput
"""
import dataclasses
import time

import jax

from benchmarks.common import emit

BATCH = (2, 4)
MESH_SHAPES = ((2, 2),)
# mixed prompt lengths: short chat turns next to long-context requests
MIX = (2, 5, 9, 14, 20, 3, 12, 7)


def _tiny_cfg():
    from repro.configs import get_config

    cfg = get_config("qwen3-14b", reduced=True)
    return dataclasses.replace(
        cfg,
        num_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=128,
        num_heads=2,
        num_kv_heads=1,
        head_dim=32,
    )


def _prompts(cfg, requests):
    return [
        [(7 * i + j) % cfg.vocab_size for j in range(MIX[i % len(MIX)])]
        for i in range(requests)
    ]


def _drain(engine, prompts, max_new):
    for p in prompts:
        engine.submit(p, max_new_tokens=max_new)
    t0 = time.perf_counter()
    engine.run_until_drained()
    return time.perf_counter() - t0


def _registry_cells(rec, dt):
    """tok/s, occupancy and TTFT for a measured drain — read from the
    recorder's registry, the same numbers ``--metrics`` serving reports."""
    reg = rec.registry
    n_tok = int(reg.value("serve_generated_tokens_total"))
    occ = reg.find("serve_batch_occupancy")[0]
    ttft = reg.find("serve_ttft_seconds")[0]
    return n_tok, {
        "tok_s": n_tok / max(dt, 1e-9),
        "occupancy": occ.mean,
        "ttft_ms": ttft.mean * 1e3,
    }


def _build(kind, params, cfg, batch, mesh, rec):
    from repro.serving import FixedSlotEngine, ServeEngine

    if kind == "fixed":
        return FixedSlotEngine(
            params, cfg, slots=batch, max_len=64, mesh=mesh, recorder=rec
        )
    return ServeEngine(
        params,
        cfg,
        max_batch=batch,
        max_len=64,
        page_size=16,
        prefill_chunk=8,
        mesh=mesh,
        recorder=rec,
    )


def run(requests: int = 8, max_new: int = 8) -> None:
    from repro.models import model as MD
    from repro.serving import Recorder

    cfg = _tiny_cfg()
    params = MD.init_params(cfg, jax.random.PRNGKey(0))
    prompts = _prompts(cfg, requests)

    n_dev = len(jax.devices())
    meshes = [None] + [
        jax.make_mesh((d, m), ("data", "model"))
        for d, m in MESH_SHAPES
        if d * m <= n_dev
    ]
    for mesh in meshes:
        tag = "1x1" if mesh is None else f"{mesh.shape['data']}x{mesh.shape['model']}"
        for batch in BATCH:
            tok_s = {}
            for kind in ("fixed", "paged"):
                rec = Recorder(trace=False)
                engine = _build(kind, params, cfg, batch, mesh, rec)
                # first drain warms the compiled prefill/decode, second is
                # timed — same mixed workload for both engines; the reset
                # drops warm-up samples (and jit compiles) from the cells
                _drain(engine, prompts[:1], 2)
                rec.reset()
                dt = _drain(engine, prompts, max_new)
                n_tok, cells = _registry_cells(rec, dt)
                tok_s[kind] = cells["tok_s"]
                emit(
                    f"serve/mesh{tag}/{kind}/batch{batch}",
                    dt / max(n_tok, 1) * 1e6,
                    f"tok_s={cells['tok_s']:.1f};"
                    f"occupancy={cells['occupancy']:.2f};"
                    f"ttft_ms={cells['ttft_ms']:.2f};requests={requests};"
                    f"max_new={max_new};mix={'-'.join(map(str, MIX))}",
                )
            emit(
                f"serve/mesh{tag}/paged_vs_fixed/batch{batch}",
                0.0,
                f"ratio={tok_s['paged'] / max(tok_s['fixed'], 1e-9):.2f};"
                f"paged_tok_s={tok_s['paged']:.1f};"
                f"fixed_tok_s={tok_s['fixed']:.1f}",
            )


if __name__ == "__main__":
    run()
