"""Paper Fig. 11: pruned vs unpruned LUT-MU resource growth as resolution
(I/d_sub) rises.  Resource proxy = LUT bytes (FPGA-LUT stand-in).

Extended with a wall-clock backend sweep through the unified execution
engine (``kernels.dispatch.lutmu_matmul``): every (d_sub, I) point times the
ref / unfused / fused backends on the same inputs and reports which one
``backend="auto"`` would pick — so the dispatch heuristics are measured,
not guessed.  On CPU the Pallas backends run in interpret mode (correctness
cost model only); run on TPU for real numbers.
"""
import jax.numpy as jnp

from benchmarks.common import emit, random_lutmu_params, sweep_backends
from repro.core.maddness import HashTree
from repro.core.pruning import plan_from_consumer_tree, pruned_param_bytes
from repro.kernels.dispatch import select_backend


def run(batch: int = 256, timed: bool = True) -> None:
    d_in = d_out = 256
    for d_sub in (8, 16):
        for depth in (3, 4, 5):
            c = d_in // d_sub
            c_next = d_out // d_sub
            unpruned = pruned_param_bytes(c, depth, d_out, None, itemsize=1)
            tree = HashTree(jnp.zeros((c_next, depth), jnp.int32),
                            jnp.zeros((c_next, 2**depth - 1), jnp.float32))
            plan = plan_from_consumer_tree(tree, d_out)
            pruned = pruned_param_bytes(c, depth, d_out, plan, itemsize=1)
            emit(f"fig11/{d_sub}x{2**depth}", 0.0,
                 f"resolution={depth / d_sub:.3f};unpruned_bytes={unpruned};"
                 f"pruned_bytes={pruned};saving={unpruned / pruned:.2f}x")

            if not timed:
                continue
            xs, params = random_lutmu_params(batch, c, d_out, depth)
            times = sweep_backends(xs, params)
            auto = select_backend(batch, c, d_out, depth, params.lut.dtype)
            for be, us in times.items():
                emit(f"fig11/{d_sub}x{2**depth}/backend={be}", us,
                     f"B={batch};C={c};N={d_out};I={depth};auto_pick={auto}")


if __name__ == "__main__":
    run()
