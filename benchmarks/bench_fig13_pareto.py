"""Paper Fig. 13 / Table I: power–II Pareto across partition factors (S, E),
LUT-MU vs MVAU, plus measured µs/call of our MXU-path aggregation (the TPU
analogue of the partition DSE: kernel block shapes).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_us
from repro.core import ii_model
from repro.core import maddness as M


def run() -> None:
    # --- analytic Pareto (paper's FPGA model) -----------------------------
    # SFC layer 2: (256, 256) weight; LUT shape (32, 8, 48): C_in=32, I=4
    for (s, e) in ((2, 1), (4, 1), (4, 2), (8, 1), (8, 4)):
        cfg = ii_model.LutMuConfig(c_in=32, depth_in=4, c_out=12,
                                   depth_out=4, s=s, e=e)
        ii = ii_model.initiation_interval(cfg)
        mw = ii_model.power_proxy_mw(cfg)
        fps = ii_model.throughput_fps(cfg)
        emit(f"fig13/lutmu_S{s}E{e}", 0.0,
             f"II={ii:.0f};power_mw={mw:.0f};fps={fps:.2e}")
    # MVAU baseline: II = fold = (256/SIMD)(256/PE)
    for (pe, simd) in ((16, 16), (32, 32), (64, 64), (128, 128)):
        fold = (256 // simd) * (256 // pe)
        # power proxy ∝ PE·SIMD MAC array
        mw = 60 + 0.02 * pe * simd
        emit(f"fig13/mvau_PE{pe}", 0.0,
             f"II={fold};power_mw={mw:.0f};fps={1e8 / max(fold, 10):.2e}")

    # --- measured µs/call of the one-hot aggregation across tilings -------
    rng = np.random.default_rng(0)
    x = rng.normal(size=(1024, 256)).astype(np.float32)
    w = rng.normal(size=(256, 256)).astype(np.float32)
    p = M.fit_maddness(x[:512], w, 32, depth=4, optimize_prototypes=False)
    xt = jnp.asarray(x)

    fn = jax.jit(lambda v: M.maddness_matmul_onehot(v, p))
    us = time_us(fn, xt)
    emit("fig13/measured_onehot_path", us, "shape=1024x256x256")
    fn_exact = jax.jit(lambda v: v @ jnp.asarray(w))
    us_e = time_us(fn_exact, xt)
    emit("fig13/measured_exact_matmul", us_e, "shape=1024x256x256")


if __name__ == "__main__":
    run()
