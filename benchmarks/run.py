"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; ``--json PATH`` additionally
writes the collected records plus the per-module failure list as JSON (the
CI ``bench-smoke`` job uploads it as the perf-trajectory artifact and gates
on the exit code).  ``--only`` selects a comma-separated subset of module
suffixes (e.g. ``--only fig1_scaling,serve_throughput``) for reduced
sweeps.  Figure benches reproduce the paper's relative claims at reduced
scale; table2 reads the dry-run roofline artifacts when present.
"""
import argparse
import importlib
import json
import sys
import traceback
from pathlib import Path

# execution order: cheap analytic sweeps first, end-to-end serving last
MODULES = ("fig1_scaling", "fig11_scalability", "fig12_problem_size",
           "fig13_pareto", "table2_e2e", "fig10_depth", "fig9_pruning",
           "resolution_configs", "serve_throughput", "prefix_reuse",
           "speculative", "obs_overhead")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", dest="json_path", metavar="PATH",
                    help="write {records, failed, errors, ok} JSON here")
    ap.add_argument("--only", metavar="MOD[,MOD...]",
                    help="run only these module suffixes "
                         f"(known: {', '.join(MODULES)})")
    args = ap.parse_args(argv)

    names = list(MODULES)
    if args.only:
        wanted = [w.strip() for w in args.only.split(",") if w.strip()]
        unknown = [w for w in wanted if w not in MODULES]
        if unknown:
            ap.error(f"unknown modules {unknown}; known: {list(MODULES)}")
        names = [n for n in MODULES if n in wanted]

    from benchmarks import common
    common.reset_records()
    print("name,us_per_call,derived")
    failed, errors = [], {}
    for name in names:
        modname = f"benchmarks.bench_{name}"
        try:
            importlib.import_module(modname).run()
        except Exception as e:  # noqa — import errors must reach the JSON too
            traceback.print_exc()
            failed.append(modname)
            errors[modname] = repr(e)
    if args.json_path:
        payload = {"records": common.RECORDS, "failed": failed,
                   "errors": errors, "ok": not failed}
        Path(args.json_path).write_text(json.dumps(payload, indent=2))
        print(f"[bench] wrote {len(common.RECORDS)} records → "
              f"{args.json_path}", file=sys.stderr)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
