"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Figure benches reproduce the
paper's relative claims at reduced scale; table2 reads the dry-run roofline
artifacts when present.
"""
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_fig1_scaling, bench_fig9_pruning,
                            bench_fig10_depth, bench_fig11_scalability,
                            bench_fig12_problem_size, bench_fig13_pareto,
                            bench_resolution_configs, bench_table2_e2e)
    print("name,us_per_call,derived")
    failed = []
    for mod in (bench_fig1_scaling, bench_fig11_scalability,
                bench_fig12_problem_size, bench_fig13_pareto,
                bench_table2_e2e, bench_fig10_depth, bench_fig9_pruning,
                bench_resolution_configs):
        try:
            mod.run()
        except Exception as e:  # noqa
            traceback.print_exc()
            failed.append(mod.__name__)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
