"""Prefix-sharing KV reuse: shared-prefix admissions vs cold starts.

The PR-8 tentpole claim, measured: a workload of requests sharing a long
common stem admits against the radix prefix index — cached full pages map
read-only, the partially-matched page COW-clones, and chunked prefill
runs only the uncovered tail — so time-to-first-token drops versus an
identical engine with ``prefix_cache=False`` that re-prefills the stem
for every request.

Both engines are primed with one stem-bearing request (warming the jit
caches, and — on the warm engine — populating the index), the recorder
is reset, and the same shared-stem workload is drained.  Emitted cells
(all read from the PR-7 metrics registry):

  * ``serve/prefix_reuse/warm``  — TTFT/tok_s with the radix index on,
    plus prefix-hit and reused-token counters;
  * ``serve/prefix_reuse/cold``  — the same workload, index off;
  * ``serve/prefix_reuse/warm_vs_cold`` — the gated record:
    ``ttft_ratio`` = cold TTFT / warm TTFT (must stay ≥ the
    ``check_trajectory.py --min-prefix-ratio`` floor) and ``hits`` /
    ``reused_tokens`` (must be > 0: the reuse path cannot silently fall
    out of the measured surface).

Run:  PYTHONPATH=src python -m benchmarks.run --only prefix_reuse
"""
import dataclasses
import time

import jax

from benchmarks.common import emit

STEM_LEN = 24   # 3 prefill chunks of shared stem per request
PAGE_SIZE = 8
CHUNK = 8


def _tiny_cfg():
    from repro.configs import get_config

    cfg = get_config("qwen3-14b", reduced=True)
    return dataclasses.replace(
        cfg,
        num_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=128,
        num_heads=2,
        num_kv_heads=1,
        head_dim=32,
    )


def run(requests: int = 8, max_new: int = 4) -> None:
    from repro.models import model as MD
    from repro.serving import Recorder, ServeEngine

    cfg = _tiny_cfg()
    params = MD.init_params(cfg, jax.random.PRNGKey(0))
    stem = [(11 * j) % cfg.vocab_size for j in range(STEM_LEN)]
    prompts = [stem + [i + 1, i + 2] for i in range(requests)]

    def measure(prefix_cache):
        rec = Recorder(trace=False)
        eng = ServeEngine(params, cfg, max_batch=2, max_len=64,
                          page_size=PAGE_SIZE, prefill_chunk=CHUNK,
                          prefix_cache=prefix_cache, recorder=rec)
        # prime: warms the compiled prefill/decode and (warm engine only)
        # indexes the stem, so every measured admission can hit
        eng.submit(stem + [125], max_new_tokens=2)
        eng.run_until_drained()
        rec.reset()
        for p in prompts:
            eng.submit(p, max_new_tokens=max_new)
        t0 = time.perf_counter()
        eng.run_until_drained()
        dt = time.perf_counter() - t0
        reg = rec.registry
        return {
            "dt": dt,
            "n_tok": int(reg.value("serve_generated_tokens_total")),
            "ttft_ms": reg.find("serve_ttft_seconds")[0].mean * 1e3,
            "hits": int(reg.value("serve_prefix_lookups_total",
                                  result="hit")),
            "reused": int(reg.value("serve_prefix_reused_tokens_total")),
            "cow": int(reg.value("serve_cow_clones_total")),
        }

    cells = {}
    for kind, on in (("cold", False), ("warm", True)):
        c = cells[kind] = measure(on)
        emit(
            f"serve/prefix_reuse/{kind}",
            c["dt"] / max(c["n_tok"], 1) * 1e6,
            f"tok_s={c['n_tok'] / max(c['dt'], 1e-9):.1f};"
            f"ttft_ms={c['ttft_ms']:.2f};hits={c['hits']};"
            f"reused_tokens={c['reused']};cow_clones={c['cow']};"
            f"requests={requests};stem={STEM_LEN};max_new={max_new}",
        )
    warm, cold = cells["warm"], cells["cold"]
    emit(
        "serve/prefix_reuse/warm_vs_cold",
        0.0,
        f"ttft_ratio={cold['ttft_ms'] / max(warm['ttft_ms'], 1e-9):.2f};"
        f"hits={warm['hits']};reused_tokens={warm['reused']};"
        f"warm_ttft_ms={warm['ttft_ms']:.2f};"
        f"cold_ttft_ms={cold['ttft_ms']:.2f}",
    )


if __name__ == "__main__":
    run()
