"""Paper Fig. 12: LUT resource occupancy vs problem size (D_in, D_out) under
three LUT configurations:

  (a) fixed d_sub for input and output  → pruned grows with D (C grows);
  (b) fixed input d_sub, fixed output C → pruned growth mitigated;
  (c) fixed C both sides               → pruned footprint ~constant
      (the paper's key scalability result).
"""
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.maddness import HashTree
from repro.core.pruning import plan_from_consumer_tree, pruned_param_bytes


def _bytes(c_in, depth, d_out, c_next):
    unpruned = pruned_param_bytes(c_in, depth, d_out, None, itemsize=1)
    tree = HashTree(jnp.zeros((c_next, depth), jnp.int32),
                    jnp.zeros((c_next, 2**depth - 1), jnp.float32))
    plan = plan_from_consumer_tree(tree, d_out)
    pruned = pruned_param_bytes(c_in, depth, d_out, plan, itemsize=1)
    return unpruned, pruned


def run() -> None:
    depth = 4
    for d in (64, 128, 256):
        # (a) fixed d_sub = 8 on both sides
        u, p = _bytes(d // 8, depth, d, d // 8)
        emit(f"fig12/dsub_both/{d}", 0.0, f"unpruned={u};pruned={p}")
        # (b) input d_sub = 8, output C = 8 fixed
        u, p = _bytes(d // 8, depth, d, 8)
        emit(f"fig12/dsub_in_Cout/{d}", 0.0, f"unpruned={u};pruned={p}")
        # (c) fixed C = 8 both sides → pruned is constant in d
        u, p = _bytes(8, depth, d, 8)
        emit(f"fig12/C_both/{d}", 0.0, f"unpruned={u};pruned={p}")


if __name__ == "__main__":
    run()
