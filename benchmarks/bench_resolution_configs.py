"""Resolution-config sweep: the paper's int-precision resource knob, end to
end through the offline compiler and the unified online engine.

For each resolution config (float32 / int16 / int8 / int4) the same
calibrated two-layer cascade is compiled (prune → quantise → pack), then
its layers run through ``lutmu_matmul``.  Emitted per config:

  * ``us`` — median µs/call of the full chain through the engine;
  * ``lut_bytes`` — shipped (pruned+quantised) LUT bytes from the
    compiler's resource report (the paper's FPGA-LUT resource proxy);
  * ``rel_err`` — output error vs the exact dense cascade (the
    accuracy-vs-resource trade-off axis of the paper's Figs. 11–13).

Run:  PYTHONPATH=src python -m benchmarks.bench_resolution_configs
"""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_us
from repro.compiler import RESOLUTIONS, compile_chain


def run(batch: int = 256) -> None:
    rng = np.random.default_rng(0)
    d, h, o = 128, 128, 64
    centers = rng.normal(size=(48, d)).astype(np.float32)
    calib = (centers[rng.integers(0, 48, 2048)]
             + 0.05 * rng.normal(size=(2048, d)).astype(np.float32))
    w0 = (rng.normal(size=(d, h)) / np.sqrt(d)).astype(np.float32)
    w1 = (rng.normal(size=(h, o)) / np.sqrt(h)).astype(np.float32)
    b0 = 0.1 * rng.normal(size=(h,)).astype(np.float32)
    b1 = 0.1 * rng.normal(size=(o,)).astype(np.float32)

    x_np = (centers[rng.integers(0, 48, batch)]
            + 0.05 * rng.normal(size=(batch, d)).astype(np.float32))
    x = jnp.asarray(x_np)
    exact = np.maximum(x_np @ w0 + b0, 0.0) @ w1 + b1
    exact_norm = float(np.linalg.norm(exact))

    for name in RESOLUTIONS:
        result = compile_chain(
            [w0, w1], [b0, b1], calib, num_codebooks=[16, 16],
            depths=[4, 4], activations=["relu"], resolution=name,
            batch_hint=batch)
        chain = result.chain
        us = time_us(lambda xv: chain(xv), x)
        out = np.asarray(chain(x))
        rel = float(np.linalg.norm(out - exact)) / exact_norm
        cfg_rep = result.report["configs"][name]
        emit(f"resolution/{name}", us,
             f"lut_bytes={cfg_rep['pruned_lut_bytes']};"
             f"savings_vs_f32_unpruned="
             f"{cfg_rep['savings_vs_float32_unpruned']};rel_err={rel:.4f}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
